"""Execution-backend benchmark: numpy fsim vs the JIT-compiled JAX backend.

Two modes:

* **Per-layer-kind breakdown** (default): representative layer programs per
  kind — conv / depthwise / pool / dense / fused-segment, with the
  depthwise rows taken from the mobilenet dw ladder — each executed on a
  calibration batch by three backends: the numpy reference, the JAX
  backend with fusion disabled (the pre-fusion per-op chain), and the
  fused JAX backend (ALU-chain kernels + whole-segment launches). All
  three must agree bit-exactly; the interesting numbers are the
  steady-state walls, the fused-vs-unfused speedup per kind (the ALU-sweep
  fusion win shows up on the depthwise rows), and the kernel-launch
  counts, which are deterministic and therefore what ``--check-baseline``
  ratchets.

* **Autotune sweep** (``--sweep``): wall-clock of verifying a full
  ``--tune full`` sweep (every winning candidate of every resnet18 +
  mobilenet layer executed on a calibration batch against the numpy
  oracle), numpy vs jax — identical tuned cycles by the bit-exactness
  contract, only wall-clock differs.

CLI:

  PYTHONPATH=src python -m benchmarks.bench_backend \
      --batch 4 --json-out results/bench --check-baseline benchmarks/baselines

``--json-out`` writes ``BENCH_backend.json`` (per-kind rows + headline
speedups); ``--check-baseline`` compares launch counts against the
checked-in copy — fused launches may not regress upward. Wall-clock is
reported but never gated (CI machines are noisy); the headline depthwise
speedup can be gated explicitly with ``--min-alu-speedup``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core.dse import make_config
from repro.core.tps import ConvWorkload, tps_search
from repro.vta.autotune import LayerTuner
from repro.vta.network import run_network
from repro.vta.workloads import _add, _conv, network_graph, resolve_network

KINDS = ("conv", "depthwise", "pool", "dense", "fused-segment")


# ---------------------------------------------------------------------------
# Per-kind representative suite
# ---------------------------------------------------------------------------
def _conv_prog(wl, hw, **kw):
    res = tps_search(wl, hw, require_db=True)
    if not res.feasible:
        res = tps_search(wl, hw)
    from repro.vta.scheduler import schedule_conv
    return schedule_conv(wl, res.tiling, hw, **kw).program


def _suite(hw):
    """(kind, name, program, shared tensors, per-image tensor shapes).

    The depthwise rows are the mobilenet1.0 dw ladder (28x28x256 down to
    7x7x1024) — the depthwise-heavy regime the fused ALU-sweep kernel
    targets. Shapes are kept moderate so the numpy oracle finishes in
    seconds per row.
    """
    from repro.vta.compiler import compile_graph
    from repro.vta.graph import Graph
    from repro.vta.scheduler import schedule_depthwise, schedule_pool
    rng = np.random.default_rng(5)
    rows = []

    wl = ConvWorkload("c3x3", 1, 28, 28, 3, 3, 64, 64, 1, 1, 1, 1)
    rows.append(("conv", "conv3x3_28x28x64", _conv_prog(wl, hw),
                 {"wgt": rng.integers(-8, 8, (64, 64, 3, 3), dtype=np.int8)},
                 {"inp": (1, 64, 28, 28), "out": (1, 64, 28, 28)}))

    for h, c, s in ((28, 256, 1), (14, 512, 1), (7, 1024, 1), (28, 256, 2)):
        wl = ConvWorkload(f"dw{h}x{c}s{s}", 1, h, h, 3, 3, c, c, 1, 1, s, s,
                          depthwise=True)
        from repro.vta.scheduler import schedule_depthwise as _sd
        rows.append(("depthwise", wl.name, _sd(wl, hw).program,
                     {"dw_wgt": rng.integers(-8, 8, (c, 3, 3),
                                             dtype=np.int8)},
                     {"inp": (1, c, h, h), "out": (1, wl.fo, wl.oh, wl.ow)}))

    wl = ConvWorkload("pool", 1, 28, 28, 3, 3, 128, 128, 1, 1, 2, 2)
    rows.append(("pool", "maxpool3x3_28x28x128",
                 schedule_pool(wl, hw, mode="max").program, {},
                 {"inp": (1, 128, 28, 28), "out": (1, 128, wl.oh, wl.ow)}))

    wl = ConvWorkload("pw", 1, 14, 14, 1, 1, 256, 256, 0, 0, 1, 1)
    rows.append(("dense", "pointwise_14x14x256", _conv_prog(wl, hw),
                 {"wgt": rng.integers(-8, 8, (256, 256, 1, 1),
                                      dtype=np.int8)},
                 {"inp": (1, 256, 14, 14), "out": (1, 256, 14, 14)}))

    g = Graph(name="seg")
    g.input("image", (1, 32, 14, 14))
    g.layer(_conv("a", 1, 14, 32, 32, 3, 1, 1), "image")
    g.layer(_conv("b", 1, 14, 32, 32, 3, 1, 1), "a")
    g.residual_add("add", "b", "a", layer=_add("add", 1, 14, 32))
    seg = [s for s in compile_graph(g, hw) if s.multi][0]
    rows.append(("fused-segment", "conv_add_clip_14x14x32", seg.program,
                 {"b.wgt": rng.integers(-8, 8, (32, 32, 3, 3),
                                        dtype=np.int8)},
                 {"a": (1, 32, 14, 14), "add": (1, 32, 14, 14)}))
    return rows


def _batched(shapes, batch, rng):
    out = {}
    for name, shp in shapes.items():
        if name in ("out", "add"):
            out[name] = np.zeros((batch,) + shp, np.int8)
        else:
            out[name] = rng.integers(-128, 128, (batch,) + shp,
                                     dtype=np.int8)
    return out


def run_kinds(batch: int = 4, passes: int = 2, verbose: bool = True) -> dict:
    """Per-kind breakdown: numpy vs jax-unfused (the pre-fusion per-op
    chain) vs jax-fused, steady-state walls + launch counts, outputs
    asserted byte-identical across all three."""
    from repro.vta import fsim_jax
    from repro.vta.backend import get_backend
    hw = make_config()
    rng = np.random.default_rng(17)
    numpy_be = get_backend("numpy")
    unfused = fsim_jax.JaxBackend(alu_fusion=False, segment_fusion=False)
    fused = fsim_jax.JaxBackend()
    rows = []
    if verbose:
        print(f"== bench_backend: per-kind breakdown, batch={batch}, "
              f"steady state = pass {passes} ==")
    for kind, name, prog, shared, shapes in _suite(hw):
        data = _batched(shapes, batch, rng)
        t0 = time.perf_counter()
        o_np = numpy_be.run_batched(prog, hw, shared=shared,
                                    batched={k: v.copy()
                                             for k, v in data.items()})
        np_s = time.perf_counter() - t0
        walls, launches, outs = {}, {}, {}
        for tag, be in (("unfused", unfused), ("fused", fused)):
            for _ in range(passes):          # pass 1 pays XLA compile
                fsim_jax.reset_kernel_launch_log()
                t0 = time.perf_counter()
                o = be.run_batched(prog, hw, shared=shared,
                                   batched={k: v.copy()
                                            for k, v in data.items()})
                walls[tag] = time.perf_counter() - t0
                launches[tag] = fsim_jax.kernel_launch_log()
            outs[tag] = o
        for tag in ("unfused", "fused"):
            for t in o_np:
                assert np.array_equal(outs[tag][t], o_np[t]), \
                    f"{name}: jax-{tag} diverges from numpy on {t!r}"
        row = {"kind": kind, "name": name, "batch": batch,
               "numpy_s": round(np_s, 3),
               "unfused_s": round(walls["unfused"], 3),
               "fused_s": round(walls["fused"], 3),
               "launches_unfused": launches["unfused"],
               "launches_fused": launches["fused"],
               "insns": len(prog.order)}
        rows.append(row)
        if verbose:
            print(f"  {kind:13s} {name:22s} numpy {np_s:7.3f}s  "
                  f"unfused {walls['unfused']:7.3f}s  "
                  f"fused {walls['fused']:7.3f}s  launches "
                  f"{launches['unfused']:3d} -> {launches['fused']:3d}")

    kinds = {}
    for k in KINDS:
        sel = [r for r in rows if r["kind"] == k]
        if not sel:
            continue
        u = sum(r["unfused_s"] for r in sel)
        f = sum(r["fused_s"] for r in sel)
        kinds[k] = {"numpy_s": round(sum(r["numpy_s"] for r in sel), 3),
                    "unfused_s": round(u, 3), "fused_s": round(f, 3),
                    "fused_vs_unfused": round(u / max(f, 1e-9), 2),
                    "launches_unfused": sum(r["launches_unfused"]
                                            for r in sel),
                    "launches_fused": sum(r["launches_fused"]
                                          for r in sel)}
    out = {"rows": rows, "kinds": kinds, "batch": batch,
           "alu_sweep_speedup": kinds.get("depthwise",
                                          {}).get("fused_vs_unfused", 0.0)}
    if verbose:
        print("  -> all kinds bit-exact across numpy / jax-unfused / "
              "jax-fused")
        for k, v in kinds.items():
            print(f"  -> {k:13s} fused vs unfused: {v['fused_vs_unfused']}x "
                  f"(launches {v['launches_unfused']} -> "
                  f"{v['launches_fused']})")
        print(f"  -> headline (depthwise ALU-sweep fusion): "
              f"{out['alu_sweep_speedup']}x steady-state")
    return out


def write_json(out: dict, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_backend.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    return path


def check_baseline(out: dict, baseline_dir: str) -> list:
    """Launch-count ratchet vs the checked-in BENCH_backend.json.

    Launch counts are deterministic compile-time facts (unlike wall-clock),
    so the guard is exact: the fused path may not launch MORE kernels per
    kind than the recorded baseline. Kinds absent from the baseline are
    skipped. Returns violation strings (empty = pass).
    """
    path = os.path.join(baseline_dir, "BENCH_backend.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        base = json.load(f)
    errs = []
    for k, v in out["kinds"].items():
        b = base.get("kinds", {}).get(k)
        if b is None:
            continue
        if v["launches_fused"] > b["launches_fused"]:
            errs.append(f"{k}: fused kernel launches regressed "
                        f"{b['launches_fused']} -> {v['launches_fused']}")
    return errs


# ---------------------------------------------------------------------------
# Full autotune-sweep mode (--sweep)
# ---------------------------------------------------------------------------
def run(nets=("resnet18", "mobilenet1.0"), batch: int = 8,
        backends=("numpy", "jax"), passes: int = 2,
        verbose: bool = True) -> dict:
    """``passes``: the jax backend pays XLA compilation on first sight of
    each chunk structure; pass 2+ measures the steady state (what repeated
    sweeps, pool workers and CI hit — executables persist on disk via the
    XLA compilation cache). The numpy interpreter has no warmup, so only
    its first pass is kept."""
    hw = make_config()
    rows = []
    if verbose:
        print(f"== bench_backend: full autotune sweep, verify batch={batch}, "
              f"default config ==")
    for be in backends:
        for p in range(passes if be != "numpy" else 1):
            tuner = LayerTuner(mode="full", backend=be, verify_batch=batch)
            t0 = time.perf_counter()
            reports = {}
            for net in nets:
                reports[net] = run_network(net, network_graph(net, 1), hw,
                                           dedup_loads=True, layer_cache={},
                                           tuner=tuner)
            wall = time.perf_counter() - t0
            row = {"backend": be, "batch": batch, "pass": p,
                   "verify_s": round(tuner.verify_seconds, 2),
                   "sweep_s": round(wall, 2),
                   "searches": tuner.searches,
                   "cycles": {n: r.total_cycles for n, r in reports.items()}}
            rows.append(row)
            if verbose:
                tag = "" if be == "numpy" else (
                    " (cold: + XLA compile)" if p == 0 else " (steady state)")
                print(f"  {be:6s}: verification {row['verify_s']:7.2f}s of "
                      f"{row['sweep_s']:7.2f}s sweep "
                      f"({tuner.searches} layer searches){tag}")
    out = {"rows": rows}
    if len({r["backend"] for r in rows}) == 2:
        a = next(r for r in rows if r["backend"] == rows[0]["backend"])
        b = rows[-1]                     # final pass of the second backend
        assert all(r["cycles"] == a["cycles"] for r in rows), \
            "backends disagree on tuned cycles"
        out["verify_speedup"] = round(a["verify_s"] / max(b["verify_s"], 1e-9),
                                      2)
        out["sweep_speedup"] = round(a["sweep_s"] / max(b["sweep_s"], 1e-9), 2)
        if verbose:
            print("  -> identical tuned cycles on both backends")
            print(f"  -> steady-state verification speedup "
                  f"{out['verify_speedup']}x, whole-sweep "
                  f"{out['sweep_speedup']}x "
                  f"({a['backend']} -> {b['backend']})")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.bench_backend")
    ap.add_argument("--batch", type=int, default=4,
                    help="calibration images per run (default 4)")
    ap.add_argument("--passes", type=int, default=2,
                    help="jax passes (pass 1 pays XLA compile; the last "
                         "pass is the steady-state measurement)")
    ap.add_argument("--json-out", default=None,
                    help="directory to write BENCH_backend.json into")
    ap.add_argument("--check-baseline", default=None,
                    help="directory holding the checked-in "
                         "BENCH_backend.json launch-count baseline")
    ap.add_argument("--min-alu-speedup", type=float, default=None,
                    help="fail unless the depthwise fused-vs-unfused "
                         "steady-state speedup reaches this")
    ap.add_argument("--sweep", action="store_true",
                    help="also run the full autotune-sweep comparison "
                         "(slow: tunes resnet18 + mobilenet end to end)")
    ap.add_argument("--no-sweep", action="store_true",
                    help="accepted for compatibility; the sweep is already "
                         "opt-in via --sweep")
    ap.add_argument("--nets", default="resnet18,mobilenet",
                    help="networks for --sweep mode")
    ap.add_argument("--backends", default="numpy,jax",
                    help="backends for --sweep mode")
    args = ap.parse_args(argv)

    out = run_kinds(batch=args.batch, passes=args.passes)
    rc = 0
    if args.min_alu_speedup is not None and \
            out["alu_sweep_speedup"] < args.min_alu_speedup:
        print(f"FAIL: depthwise fused-vs-unfused speedup "
              f"{out['alu_sweep_speedup']}x < required "
              f"{args.min_alu_speedup}x", file=sys.stderr)
        rc = 1
    if args.check_baseline:
        errs = check_baseline(out, args.check_baseline)
        for e in errs:
            print(f"BASELINE VIOLATION: {e}", file=sys.stderr)
        rc = rc or (1 if errs else 0)
    if args.sweep and not args.no_sweep:
        nets = tuple(resolve_network(n) for n in args.nets.split(",") if n)
        backends = tuple(b for b in args.backends.split(",") if b)
        out["sweep"] = run(nets=nets, batch=args.batch, backends=backends,
                           passes=args.passes)
    if args.json_out:
        print(f"wrote {write_json(out, args.json_out)}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
