"""Execution-backend benchmark: numpy fsim vs the JIT-compiled JAX backend.

Measures the acceptance metric of the backend layer: wall-clock of
*verifying a full autotune sweep* (``--tune full``: every winning candidate
of every resnet18 + mobilenet layer executed functionally on a calibration
batch and compared bit-exactly against the numpy oracle), numpy
interpreter vs ``jax.jit``/vmap — identical verdicts by the bit-exactness
contract, only wall-clock differs.

CLI:

  PYTHONPATH=src python -m benchmarks.bench_backend \
      --nets resnet18,mobilenet --batch 8
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.core.dse import make_config
from repro.vta.autotune import LayerTuner
from repro.vta.network import run_network
from repro.vta.workloads import network_graph, resolve_network


def run(nets=("resnet18", "mobilenet1.0"), batch: int = 8,
        backends=("numpy", "jax"), passes: int = 2,
        verbose: bool = True) -> dict:
    """``passes``: the jax backend pays XLA compilation on first sight of
    each chunk structure; pass 2+ measures the steady state (what repeated
    sweeps, pool workers and CI hit — executables persist on disk via the
    XLA compilation cache). The numpy interpreter has no warmup, so only
    its first pass is kept."""
    hw = make_config()
    rows = []
    if verbose:
        print(f"== bench_backend: full autotune sweep, verify batch={batch}, "
              f"default config ==")
    for be in backends:
        for p in range(passes if be != "numpy" else 1):
            tuner = LayerTuner(mode="full", backend=be, verify_batch=batch)
            t0 = time.perf_counter()
            reports = {}
            for net in nets:
                reports[net] = run_network(net, network_graph(net, 1), hw,
                                           dedup_loads=True, layer_cache={},
                                           tuner=tuner)
            wall = time.perf_counter() - t0
            row = {"backend": be, "batch": batch, "pass": p,
                   "verify_s": round(tuner.verify_seconds, 2),
                   "sweep_s": round(wall, 2),
                   "searches": tuner.searches,
                   "cycles": {n: r.total_cycles for n, r in reports.items()}}
            rows.append(row)
            if verbose:
                tag = "" if be == "numpy" else (
                    " (cold: + XLA compile)" if p == 0 else " (steady state)")
                print(f"  {be:6s}: verification {row['verify_s']:7.2f}s of "
                      f"{row['sweep_s']:7.2f}s sweep "
                      f"({tuner.searches} layer searches){tag}")
    out = {"rows": rows}
    if len({r["backend"] for r in rows}) == 2:
        a = next(r for r in rows if r["backend"] == rows[0]["backend"])
        b = rows[-1]                     # final pass of the second backend
        assert all(r["cycles"] == a["cycles"] for r in rows), \
            "backends disagree on tuned cycles"
        out["verify_speedup"] = round(a["verify_s"] / max(b["verify_s"], 1e-9),
                                      2)
        out["sweep_speedup"] = round(a["sweep_s"] / max(b["sweep_s"], 1e-9), 2)
        if verbose:
            print("  -> identical tuned cycles on both backends")
            print(f"  -> steady-state verification speedup "
                  f"{out['verify_speedup']}x, whole-sweep "
                  f"{out['sweep_speedup']}x "
                  f"({a['backend']} -> {b['backend']})")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.bench_backend")
    ap.add_argument("--nets", default="resnet18,mobilenet")
    ap.add_argument("--batch", type=int, default=8,
                    help="calibration images per verification (default 8)")
    ap.add_argument("--backends", default="numpy,jax")
    ap.add_argument("--passes", type=int, default=2,
                    help="jax passes (pass 1 pays XLA compile; the last "
                         "pass is the steady-state measurement)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail unless the verification speedup reaches this")
    args = ap.parse_args(argv)
    nets = tuple(resolve_network(n) for n in args.nets.split(",") if n)
    backends = tuple(b for b in args.backends.split(",") if b)
    out = run(nets=nets, batch=args.batch, backends=backends,
              passes=args.passes)
    if args.min_speedup is not None:
        if out.get("verify_speedup", 0) < args.min_speedup:
            print(f"FAIL: verification speedup {out.get('verify_speedup')}x "
                  f"< required {args.min_speedup}x", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
