"""Paper §IV.D.1 / Fig 10: TPS vs fallback DRAM bytes, ResNet-18 C2-C11 on
BLOCK_IN=BLOCK_OUT=32. Paper claim: 20x-400x reduction."""
from __future__ import annotations

from repro.core.tps import fallback_tiling, tps_search
from repro.vta.isa import VTAConfig
from repro.vta.workloads import resnet18_convs


def run(verbose: bool = True) -> dict:
    hw = VTAConfig(log_block_in=5, log_block_out=5,
                   log_wgt_buff=20, log_acc_buff=18, log_inp_buff=16)
    rows = []
    for wl in resnet18_convs():
        res = tps_search(wl, hw)
        fb = fallback_tiling(wl, hw)
        assert res.feasible, wl
        rows.append({"layer": wl.name.split(".")[-1],
                     "fallback_bytes": fb.cost_bytes,
                     "tps_bytes": res.tiling.cost_bytes,
                     "ratio": fb.cost_bytes / res.tiling.cost_bytes,
                     "tiling": res.tiling})
    ratios = [r["ratio"] for r in rows]
    out = {"rows": rows, "min_ratio": min(ratios), "max_ratio": max(ratios),
           "paper_range": (20, 400)}
    if verbose:
        print("== bench_tps (paper Fig 10: 20x-400x, C2-C11 @ BLOCK=32) ==")
        for r in rows:
            print(f"  {r['layer']:>4s}: fallback {r['fallback_bytes']/1e6:9.2f}MB"
                  f"  TPS {r['tps_bytes']/1e6:8.3f}MB  ratio {r['ratio']:7.1f}x")
        print(f"  range: {out['min_ratio']:.0f}x .. {out['max_ratio']:.0f}x"
              f"   [paper: 20x .. 400x]")
    return out


if __name__ == "__main__":
    run()
