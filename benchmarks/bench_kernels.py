"""TPU-plane kernel bench: TPS-for-BlockSpecs tile table + interpret-mode
validation timings for the Pallas kernels (the §Roofline/§Perf substrate)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tile_search import (select_attention_tile, select_gemm_tile)
from repro.kernels import ops, ref


def run(verbose: bool = True) -> dict:
    shapes = [
        ("qwen3 qkv", 4096, 2048 + 2048, 1024),
        ("qwen2.5 ffn", 4096, 27648, 5120),
        ("deepseek ffn", 4096, 22016, 8192),
        ("mixtral expert", 8192, 16384, 6144),
        ("lm head", 4096, 151936, 1024),
    ]
    tiles = []
    if verbose:
        print("== bench_kernels: TPS-selected matmul tiles (bf16, 64MiB VMEM) ==")
    for name, M, N, K in shapes:
        t = select_gemm_tile(M, N, K, in_bytes=2)
        tiles.append({"name": name, "mnk": (M, N, K),
                      "tile": (t.bm, t.bn, t.bk),
                      "vmem_mib": t.vmem_bytes / 2 ** 20,
                      "traffic_gib": t.traffic_bytes / 2 ** 30})
        if verbose:
            print(f"  {name:16s} M{M} N{N} K{K}: tile ({t.bm},{t.bn},{t.bk})"
                  f"  vmem {t.vmem_bytes/2**20:6.1f}MiB"
                  f"  HBM traffic {t.traffic_bytes/2**30:7.2f}GiB")
    at = select_attention_tile(32768, 32768, 128, in_bytes=2)
    if verbose:
        print(f"  flash-attn 32k:  bq={at.bq} bkv={at.bkv} "
              f"vmem {at.vmem_bytes/2**20:.1f}MiB")

    # interpret-mode correctness timing (small shapes; CPU)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 512), jnp.float32)
    w = jax.random.normal(key, (512, 384), jnp.float32)
    t0 = time.time()
    o = ops.gemm(x, w, act="relu", clip=6.0)
    o.block_until_ready()
    gemm_t = time.time() - t0
    err = float(jnp.max(jnp.abs(
        o - ref.matmul_ref(x, w, act="relu", clip=6.0))))
    if verbose:
        print(f"  gemm interpret check: err={err:.2e} ({gemm_t*1e3:.0f} ms "
              f"incl. trace+compile)")
    return {"tiles": tiles, "attn_tile": (at.bq, at.bkv), "gemm_err": err}


if __name__ == "__main__":
    run()
