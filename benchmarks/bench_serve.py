"""Serving benchmark: continuous batching vs serialized batch-1 dispatch.

Three phases over a mixed two-tenant workload (alice -> resnet18,
bob -> mobilenet, weights 2:1):

  1. **throughput** — a request burst drained through the continuous-
     batching engine (bucketed ``run_batched`` dispatches) and through a
     serialized baseline (same engine, ``buckets=(1,)`` — every request its
     own batch-1 dispatch on the same backend). The ratio is the headline
     speedup; the acceptance bar is >=3x on the jax backend.
  2. **poisson** — open-loop Poisson arrivals against a live engine on a
     background thread; reports the latency envelope (per-tenant p50/p99,
     batch occupancy, queue waits) at the offered rate.
  3. **verify** — a sample of served outputs compared bit-for-bit against
     batch-1 numpy execution (``ServedModel.run_single``), the oracle the
     engine must match by contract.

CLI:

  PYTHONPATH=src python -m benchmarks.bench_serve \
      --scale small --requests 64 --rate 100 --min-speedup 3 --verify 8

CI smoke runs the tiny scale with ``--assert-no-drops --max-p99 5`` and
uploads the ``--json`` report as an artifact (.github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.serve.engine import VTAServeEngine
from repro.serve.metrics import ServeMetrics
from repro.serve.model import served_model

TENANTS = (("alice", "resnet18", 2.0), ("bob", "mobilenet", 1.0))
POOL = 16                        # distinct images per model


def _models(scale: str) -> dict:
    return {model: served_model(model, scale) for _, model, _ in TENANTS}


def _request_mix(models: dict, n: int, seed: int) -> list:
    """n deterministic (tenant, model, image, pool_index) tuples."""
    rng = np.random.default_rng(seed)
    pools = {name: m.random_images(POOL, seed=seed + 1)
             for name, m in models.items()}
    mix = []
    for _ in range(n):
        tenant, model, _ = TENANTS[int(rng.integers(len(TENANTS)))]
        idx = int(rng.integers(POOL))
        mix.append((tenant, model, pools[model][idx], idx))
    return mix


def _engine(models: dict, backend: str, buckets: tuple, capacity: int,
            max_wait_s: float = 0.0) -> VTAServeEngine:
    eng = VTAServeEngine(models, backend=backend, buckets=buckets,
                         queue_capacity=capacity, max_wait_s=max_wait_s)
    for tenant, _, weight in TENANTS:
        eng.add_tenant(tenant, weight=weight)
    return eng


def _warmup(eng: VTAServeEngine, models: dict) -> None:
    """Pay every (chunk-spec, bucket) XLA compile outside the measurement:
    one exactly-bucket-sized burst per (model, bucket) pair."""
    for tenant, model, _ in TENANTS:
        for b in eng.scheduler.buckets:
            for img in models[model].random_images(b, seed=99):
                eng.submit(tenant, model, img)
            eng.drain()
    eng.metrics = ServeMetrics()


def _throughput_phase(models: dict, mix: list, backend: str, buckets: tuple,
                      passes: int = 2) -> tuple:
    """Drain the burst ``passes`` times and report the fastest pass — pass 1
    absorbs one-time settling (XLA buffer pools, allocator growth) like
    bench_backend's steady-state passes; best-of-N rides out scheduler
    noise on small shared runners."""
    eng = _engine(models, backend, buckets, capacity=len(mix) + 8)
    _warmup(eng, models)
    best = None
    for _ in range(passes):
        eng.metrics = ServeMetrics()
        tickets = []
        t0 = time.perf_counter()
        for tenant, model, img, _ in mix:
            tickets.append(eng.submit(tenant, model, img))
        eng.drain()
        wall = time.perf_counter() - t0
        if best is None or wall < best[0]:
            best = (wall, tickets, eng.metrics.snapshot())
    wall, tickets, snap = best
    return {"images": len(mix), "wall_s": round(wall, 4),
            "images_per_sec": round(len(mix) / wall, 2),
            "batches": snap["batches"],
            "batch_occupancy": snap["batch_occupancy"]}, tickets


def _poisson_phase(models: dict, backend: str, buckets: tuple, n: int,
                   rate: float, seed: int) -> dict:
    """Open-loop arrivals: exponential gaps at ``rate`` req/s, engine live
    on its serving thread — queue waits and padding are real, not modeled."""
    rng = np.random.default_rng(seed + 7)
    mix = _request_mix(models, n, seed + 7)
    eng = _engine(models, backend, buckets, capacity=n + 8)
    _warmup(eng, models)
    eng.start(poll_interval_s=0.0005)
    t0 = time.perf_counter()
    for tenant, model, img, _ in mix:
        time.sleep(float(rng.exponential(1.0 / rate)))
        eng.submit(tenant, model, img)
    eng.stop(drain=True)
    wall = time.perf_counter() - t0
    snap = eng.metrics.snapshot()
    snap["offered_rate_rps"] = rate
    snap["achieved_rate_rps"] = round(n / wall, 2)
    return snap


def _verify_phase(models: dict, mix: list, tickets: list, k: int) -> dict:
    idxs = np.linspace(0, len(mix) - 1, min(k, len(mix))).astype(int)
    mismatches = 0
    for i in idxs:
        _, model, img, _ = mix[i]
        ref = models[model].run_single(img, backend="numpy")
        if not (np.array_equal(tickets[i].result(timeout=5), ref)
                and np.any(ref)):
            mismatches += 1
    return {"checked": len(idxs), "mismatches": mismatches}


def run(scale: str = "small", backend: str = "jax", requests: int = 96,
        poisson_requests: int = 48, rate: float = 100.0,
        buckets: tuple = (1, 2, 4, 8, 16), seed: int = 0,
        verify: int = 8, passes: int = 4, verbose: bool = True) -> dict:
    models = _models(scale)
    mix = _request_mix(models, requests, seed)
    if verbose:
        print(f"== bench_serve: scale={scale} backend={backend} "
              f"{requests} burst + {poisson_requests} poisson "
              f"@ {rate}/s ==")

    batched, tickets = _throughput_phase(models, mix, backend, buckets,
                                         passes=passes)
    serial, _ = _throughput_phase(models, mix, backend, (1,), passes=passes)
    speedup = round(batched["images_per_sec"]
                    / max(serial["images_per_sec"], 1e-9), 2)
    if verbose:
        print(f"  batched  : {batched['images_per_sec']:8.1f} img/s "
              f"({batched['batches']} batches, occupancy "
              f"{batched['batch_occupancy']:.2f})")
        print(f"  batch-1  : {serial['images_per_sec']:8.1f} img/s "
              f"({serial['batches']} dispatches)")
        print(f"  -> continuous batching speedup {speedup}x")

    poisson = _poisson_phase(models, backend, buckets, poisson_requests,
                             rate, seed)
    dropped = sum(poisson["requests"][k]
                  for k in ("rejected", "shed", "expired"))
    if verbose:
        lat = poisson["latency_s"]
        print(f"  poisson  : offered {rate}/s achieved "
              f"{poisson['achieved_rate_rps']}/s, latency p50 "
              f"{lat['p50'] * 1e3:.1f}ms p99 {lat['p99'] * 1e3:.1f}ms, "
              f"occupancy {poisson['batch_occupancy']:.2f}, "
              f"dropped {dropped}")
        for tenant, t in sorted(poisson["per_tenant"].items()):
            print(f"    {tenant:8s}: {t['completed']:4d} done, "
                  f"p99 {t['latency_s']['p99'] * 1e3:.1f}ms")

    verified = _verify_phase(models, mix, tickets, verify)
    if verbose:
        print(f"  verify   : {verified['checked']} outputs vs batch-1 "
              f"numpy, {verified['mismatches']} mismatches")

    return {"scale": scale, "backend": backend, "buckets": list(buckets),
            "throughput": {"batched": batched, "serialized": serial,
                           "speedup": speedup},
            "poisson": poisson, "dropped": dropped, "verified": verified}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.bench_serve")
    ap.add_argument("--scale", default="small", choices=("tiny", "small"))
    ap.add_argument("--backend", default="jax")
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--poisson-requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="poisson arrival rate, requests/s")
    ap.add_argument("--buckets", default="1,2,4,8,16")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", type=int, default=8,
                    help="outputs to check bit-exactly vs batch-1 numpy")
    ap.add_argument("--passes", type=int, default=4,
                    help="throughput passes; the fastest is reported")
    ap.add_argument("--json", default=None, help="write the report here")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail unless batched/serialized reaches this")
    ap.add_argument("--max-p99", type=float, default=None,
                    help="fail if poisson p99 latency exceeds this (s)")
    ap.add_argument("--assert-no-drops", action="store_true",
                    help="fail if any request was rejected/shed/expired")
    args = ap.parse_args(argv)
    out = run(scale=args.scale, backend=args.backend,
              requests=args.requests,
              poisson_requests=args.poisson_requests, rate=args.rate,
              buckets=tuple(int(b) for b in args.buckets.split(",")),
              seed=args.seed, verify=args.verify, passes=args.passes)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"  report -> {args.json}")

    failures = []
    if out["verified"]["mismatches"]:
        failures.append(f"{out['verified']['mismatches']} outputs diverge "
                        f"from batch-1 numpy")
    if args.min_speedup is not None \
            and out["throughput"]["speedup"] < args.min_speedup:
        failures.append(f"speedup {out['throughput']['speedup']}x < "
                        f"required {args.min_speedup}x")
    if args.max_p99 is not None \
            and out["poisson"]["latency_s"]["p99"] > args.max_p99:
        failures.append(f"poisson p99 {out['poisson']['latency_s']['p99']}s "
                        f"> bound {args.max_p99}s")
    if args.assert_no_drops and out["dropped"]:
        failures.append(f"{out['dropped']} requests dropped on an "
                        f"unsaturated load")
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
