"""Serving benchmark: continuous batching vs serialized batch-1 dispatch.

Four phases over a mixed two-tenant workload (alice -> resnet18,
bob -> mobilenet, weights 2:1):

  1. **throughput** — a request burst drained through the continuous-
     batching engine (bucketed ``run_batched`` dispatches) and through a
     serialized baseline (same engine, ``buckets=(1,)`` — every request its
     own batch-1 dispatch on the same backend). The ratio is the headline
     speedup; the acceptance bar is >=3x on the jax backend.
  2. **poisson** — open-loop Poisson arrivals against a live engine on a
     background thread; reports the latency envelope (per-tenant p50/p99,
     batch occupancy, queue waits) at the offered rate.
  3. **verify** — a sample of served outputs compared bit-for-bit against
     batch-1 numpy execution (``ServedModel.run_single``), the oracle the
     engine must match by contract.
  4. **chaos** — Poisson load on a FakeClock against a supervised engine
     with a *seeded* ``FaultPlan`` (transient executor crashes, one
     watchdog-tripping hang, a persistent top-rung kernel-impl fault,
     poisoned payloads) running on the degradation ladder
     (serve/breaker.py). Asserts total supervision: every ticket resolves,
     the engine survives, poisoned requests are isolated by bisection, the
     breaker demotes and recovers via a half-open probe, and every served
     output stays bit-exact vs the numpy oracle. Entirely deterministic —
     the injected clock and seeded faults make its counters a baseline CI
     can diff exactly (``--json-out``/``--check-baseline``,
     benchmarks/baselines/BENCH_serve.json).

  5. **scaleout** — horizontal scaling over the worker pool
     (serve/workers.py, docs/scaling.md). Two parts: a burst drained at 1
     worker vs ``--workers`` N over the thread transport, each worker a
     modeled accelerator instance (real compute + a fixed device service
     floor, ``--device-latency``) so dispatch-path concurrency is what is
     measured on a shared CPU runner; and a deterministic *death drill* —
     FakeClock + inline transport + a seeded ``worker.die``/``worker.stall``
     plan — asserting the failure contract (dead worker's batches requeue
     whole onto survivors, zero unresolved tickets, surviving outputs
     bit-exact) and that two same-seed runs produce byte-identical
     fault/metric logs. Always runs the tiny model scale.

CLI:

  PYTHONPATH=src python -m benchmarks.bench_serve \
      --scale small --requests 64 --rate 100 --min-speedup 3 --verify 8
  PYTHONPATH=src python -m benchmarks.bench_serve \
      --phases chaos --seed 7 --json-out results/bench \
      --check-baseline benchmarks/baselines
  PYTHONPATH=src python -m benchmarks.bench_serve \
      --phases scaleout --workers 2 --min-scaleout-speedup 1.8

CI smoke runs the tiny scale with ``--assert-no-drops --max-p99 5`` and
uploads the ``--json`` report as an artifact; the ``chaos-smoke`` job runs
``--phases chaos`` with a pinned seed and asserts zero unresolved tickets
plus breaker recovery from the report; both also run the scaleout phase
with ``--workers 2`` (.github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from collections import Counter
from typing import Optional

import numpy as np

from repro.serve.clock import FakeClock
from repro.serve.engine import VTAServeEngine
from repro.serve.metrics import ServeMetrics
from repro.serve.model import served_model

TENANTS = (("alice", "resnet18", 2.0), ("bob", "mobilenet", 1.0))
POOL = 16                        # distinct images per model
DEFAULT_PHASES = ("throughput", "poisson", "verify", "chaos", "scaleout")
CHAOS_EXEC_COST_S = 0.02         # modeled fake-clock cost per dispatch
DEVICE_LATENCY_S = 0.05          # modeled per-dispatch device service time


def _models(scale: str) -> dict:
    return {model: served_model(model, scale) for _, model, _ in TENANTS}


def _request_mix(models: dict, n: int, seed: int) -> list:
    """n deterministic (tenant, model, image, pool_index) tuples."""
    rng = np.random.default_rng(seed)
    pools = {name: m.random_images(POOL, seed=seed + 1)
             for name, m in models.items()}
    mix = []
    for _ in range(n):
        tenant, model, _ = TENANTS[int(rng.integers(len(TENANTS)))]
        idx = int(rng.integers(POOL))
        mix.append((tenant, model, pools[model][idx], idx))
    return mix


def _engine(models: dict, backend: str, buckets: tuple, capacity: int,
            max_wait_s: float = 0.0) -> VTAServeEngine:
    eng = VTAServeEngine(models, backend=backend, buckets=buckets,
                         queue_capacity=capacity, max_wait_s=max_wait_s)
    for tenant, _, weight in TENANTS:
        eng.add_tenant(tenant, weight=weight)
    return eng


def _warmup(eng: VTAServeEngine, models: dict) -> None:
    """Pay every (chunk-spec, bucket) XLA compile outside the measurement:
    one exactly-bucket-sized burst per (model, bucket) pair."""
    for tenant, model, _ in TENANTS:
        for b in eng.scheduler.buckets:
            for img in models[model].random_images(b, seed=99):
                eng.submit(tenant, model, img)
            eng.drain()
    eng.reset_metrics()


def _throughput_phase(models: dict, mix: list, backend: str, buckets: tuple,
                      passes: int = 2) -> tuple:
    """Drain the burst ``passes`` times and report the fastest pass — pass 1
    absorbs one-time settling (XLA buffer pools, allocator growth) like
    bench_backend's steady-state passes; best-of-N rides out scheduler
    noise on small shared runners."""
    eng = _engine(models, backend, buckets, capacity=len(mix) + 8)
    _warmup(eng, models)
    best = None
    for _ in range(passes):
        eng.reset_metrics()
        tickets = []
        t0 = time.perf_counter()
        for tenant, model, img, _ in mix:
            tickets.append(eng.submit(tenant, model, img))
        eng.drain()
        wall = time.perf_counter() - t0
        if best is None or wall < best[0]:
            best = (wall, tickets, eng.metrics.snapshot())
    wall, tickets, snap = best
    return {"images": len(mix), "wall_s": round(wall, 4),
            "images_per_sec": round(len(mix) / wall, 2),
            "batches": snap["batches"],
            "batch_occupancy": snap["batch_occupancy"]}, tickets


def _poisson_phase(models: dict, backend: str, buckets: tuple, n: int,
                   rate: float, seed: int) -> dict:
    """Open-loop arrivals: exponential gaps at ``rate`` req/s, engine live
    on its serving thread — queue waits and padding are real, not modeled."""
    rng = np.random.default_rng(seed + 7)
    mix = _request_mix(models, n, seed + 7)
    eng = _engine(models, backend, buckets, capacity=n + 8)
    _warmup(eng, models)
    eng.start(poll_interval_s=0.0005)
    t0 = time.perf_counter()
    for tenant, model, img, _ in mix:
        time.sleep(float(rng.exponential(1.0 / rate)))
        eng.submit(tenant, model, img)
    eng.stop(drain=True)
    wall = time.perf_counter() - t0
    snap = eng.metrics.snapshot()
    snap["offered_rate_rps"] = rate
    snap["achieved_rate_rps"] = round(n / wall, 2)
    return snap


def _verify_phase(models: dict, mix: list, tickets: list, k: int) -> dict:
    idxs = np.linspace(0, len(mix) - 1, min(k, len(mix))).astype(int)
    mismatches = 0
    for i in idxs:
        _, model, img, _ = mix[i]
        ref = models[model].run_single(img, backend="numpy")
        if not (np.array_equal(tickets[i].result(timeout=5), ref)
                and np.any(ref)):
            mismatches += 1
    return {"checked": len(idxs), "mismatches": mismatches}


def _chaos_fault_plan(seed: int, ladder: tuple):
    """The benchmark's seeded fault mix: transient executor crashes, one
    watchdog-tripping hang, a finite persistent fault on the top rung's
    gemm implementation (trips the breaker, fails two half-open probes,
    then exhausts so the third probe recovers), and two poisoned payloads
    for bisection to isolate. Returns (plan, top-rung gemm fault key)."""
    from repro.serve.faults import FaultPlan, FaultSpec
    from repro.vta.backend import backend_kernel_impls

    impls = dict(backend_kernel_impls(ladder[0]))
    gemm_key = f"gemm:{impls['gemm']}" if "gemm" in impls else "*"
    plan = FaultPlan(seed=seed, specs=(
        FaultSpec("executor.raise", prob=0.12, times=4),
        FaultSpec("executor.hang", times=1, after=6, hang_s=1.0),
        FaultSpec("kernel.impl", key=gemm_key, times=5),
        FaultSpec("payload.bitflip", prob=0.3, times=2, after=4, bits=2),
    ))
    return plan, gemm_key


def _chaos_phase(n: int, rate: float, seed: int, ladder: tuple,
                 verbose: bool = True) -> dict:
    """Deterministic chaos: Poisson load on a FakeClock against a
    supervised engine + degradation ladder under a seeded FaultPlan.
    Always runs the tiny model scale — this phase measures reliability
    invariants and deterministic counters, not throughput."""
    from repro.serve.breaker import DegradingBackendExecutor
    from repro.serve.faults import FaultInjector

    models = _models("tiny")
    clock = FakeClock()
    metrics = ServeMetrics()
    plan, gemm_key = _chaos_fault_plan(seed, ladder)
    inj = FaultInjector(plan, clock=clock)
    executor = DegradingBackendExecutor(models, ladder, clock=clock,
                                        faults=inj, metrics=metrics,
                                        fail_threshold=3, cooldown_s=0.08)
    eng = VTAServeEngine(models, clock=clock, executor=executor,
                         metrics=metrics, faults=inj,
                         buckets=(1, 2, 4, 8), queue_capacity=n + 8,
                         max_retries=2, retry_backoff_s=0.004,
                         exec_timeout_s=0.5, requeue_budget=6)
    for tenant, _, weight in TENANTS:
        eng.add_tenant(tenant, weight=weight)

    mix = _request_mix(models, n, seed)
    gaps = np.random.default_rng(seed + 13).exponential(1.0 / rate, n)
    t0 = time.perf_counter()
    tickets = []
    for k, (tenant, model, img, _) in enumerate(mix):
        clock.advance(float(gaps[k]))
        tickets.append(eng.submit(
            tenant, model, img,
            deadline_s=20.0 if k % 5 == 0 else None))
        # step every few arrivals so poisoned requests co-batch with
        # innocents (what bisection must untangle)
        if k % 4 == 3 and eng.step():
            clock.advance(CHAOS_EXEC_COST_S)
    drained = 0
    while eng.pending() > 0 and drained < 20 * n:
        if eng.step():
            clock.advance(CHAOS_EXEC_COST_S)
        else:
            clock.advance(0.002)
        drained += 1
    wall = time.perf_counter() - t0

    unresolved = sum(1 for t in tickets if not t.done())
    statuses = Counter(t.status for t in tickets)
    poisoned_failed = sum(1 for t in tickets
                          if inj.is_poisoned(t.request.id)
                          and t.status == "failed")
    checked = mismatches = 0
    for t in tickets:
        if not t.ok:
            continue
        ref = models[t.request.model].run_single(
            np.asarray(t.request.payload), backend="numpy")
        checked += 1
        if not np.array_equal(t.request.result, ref):
            mismatches += 1
    snap = metrics.snapshot()
    breaker = executor.breaker_log()
    recovered = "half_open->closed" in breaker.get(ladder[0], [])
    out = {
        "requests": n, "rate": rate, "seed": seed, "ladder": list(ladder),
        "gemm_fault_key": gemm_key,
        "statuses": dict(sorted(statuses.items())),
        "unresolved": unresolved,
        "survived": True,                 # the drain loop returned
        "poisoned": sorted(inj.poisoned),
        "poisoned_failed": poisoned_failed,
        "fault_sites": inj.summary(),
        "fault_events": inj.events(),
        "reliability": snap["reliability"],
        "breaker": breaker,
        "breaker_recovered": recovered,
        "bitexact": {"checked": checked, "mismatches": mismatches},
        "final_backend": executor.active_backend,
        "wall_s": round(wall, 3),
    }
    if verbose:
        rel = snap["reliability"]
        print(f"  chaos    : {n} reqs, statuses {out['statuses']}, "
              f"unresolved {unresolved}")
        print(f"             faults {out['fault_sites']}, "
              f"retries {rel['retries']} bisections {rel['bisections']} "
              f"requeues {rel['requeues']} timeouts {rel['timeouts']}")
        print(f"             breaker[{ladder[0]}] "
              f"{' '.join(breaker.get(ladder[0], [])) or '(no transitions)'}"
              f", recovered={recovered}, fallbacks {rel['fallbacks']}")
        print(f"             bit-exact {checked} checked, "
              f"{mismatches} mismatches")
    return out


class _DeviceExecutor:
    """One modeled accelerator instance: the batch is computed for real on
    the configured backend (outputs stay bit-exact by construction), then
    the dispatch is padded with a GIL-releasing sleep up to a fixed device
    service time. This is the scale-out analog of ``CHAOS_EXEC_COST_S``:
    on a shared CPU runner the workers' *compute* serializes on the GIL,
    but real deployments give each worker its own accelerator — a fixed
    service floor per dispatch — and it is that dispatch-path concurrency
    (placement, inboxes, supervision) the phase measures."""

    def __init__(self, models: dict, backend: str, service_s: float):
        from repro.serve.engine import BackendExecutor
        self.inner = BackendExecutor(models, backend)
        self.service_s = service_s

    def __call__(self, model_key: str, images: list, bucket: int) -> list:
        t0 = time.perf_counter()
        outs = self.inner(model_key, images, bucket)
        rest = self.service_s - (time.perf_counter() - t0)
        if rest > 0:
            time.sleep(rest)
        return outs


def _scaleout_endpoints() -> dict:
    """The burst's served-endpoint map: two logical endpoints per tiny
    model family, sharing one compiled ``ServedModel`` each. Scale-out is
    a many-endpoints-few-workers problem — placement keys on the endpoint
    name, so four keys is the smallest map that lets the sticky affinity
    layer balance two workers instead of pinning one whole family (and
    its entire traffic share) to a single worker."""
    base = _models("tiny")
    return {f"{name}-{suffix}": m
            for name, m in base.items() for suffix in ("a", "b")}


def _scaleout_mix(endpoints: dict, n: int, seed: int) -> list:
    """Balanced deterministic round-robin over the endpoints (images drawn
    from each endpoint's seeded pool), one tenant per endpoint: equal
    per-endpoint counts make the ideal N-worker speedup actually reachable
    (a skewed mix would measure the skew, not the pool), and per-endpoint
    lanes keep each tenant queue single-model so the scheduler can
    assemble full buckets from interleaved arrivals."""
    names = sorted(endpoints)
    pools = {ep: endpoints[ep].random_images(POOL, seed=seed + 1)
             for ep in names}
    mix = []
    for i in range(n):
        ep = names[i % len(names)]
        mix.append((ep, ep, pools[ep][i % POOL], i % POOL))
    return mix


def _scaleout_burst(endpoints: dict, mix: list, backend: str,
                    buckets: tuple, n_workers: int, device_latency: float,
                    passes: int = 2) -> dict:
    """Drain the burst through a thread-transport pool of ``n_workers``
    ``_DeviceExecutor`` workers; best-of-``passes`` wall time. A fresh
    engine+pool per call — XLA compiles stay warm in-process, so pass 1 of
    the first call pays them and the warmup burst below absorbs that."""
    from repro.serve.workers import WorkerPool

    pool = WorkerPool(
        endpoints, n_workers, backend=backend, transport="thread",
        executor_factory=lambda wid: _DeviceExecutor(endpoints, backend,
                                                     device_latency))
    eng = VTAServeEngine(endpoints, backend=backend, buckets=buckets,
                         queue_capacity=len(mix) + 8, workers=pool)
    for ep in sorted(endpoints):
        eng.add_tenant(ep, weight=1.0)
    # warmup: every (endpoint, bucket) pair once — pays the XLA compiles
    # and seeds the affinity map outside the measurement
    for ep in sorted(endpoints):
        for b in eng.scheduler.buckets:
            for img in endpoints[ep].random_images(b, seed=99):
                eng.submit(ep, ep, img)
            eng.drain()
    eng.reset_metrics()
    best_wall = None
    for _ in range(passes):
        tickets = []
        t0 = time.perf_counter()
        for tenant, model, img, _ in mix:
            tickets.append(eng.submit(tenant, model, img))
        eng.drain()
        while eng.pending():
            time.sleep(1e-4)
        wall = time.perf_counter() - t0
        assert all(t.ok for t in tickets), \
            Counter(t.status for t in tickets)
        if best_wall is None or wall < best_wall:
            best_wall = wall
    snap = eng.metrics.snapshot()
    eng.close()
    return {"workers": n_workers, "images": len(mix),
            "wall_s": round(best_wall, 4),
            "images_per_sec": round(len(mix) / best_wall, 2),
            "batches": snap["batches"],
            "per_worker": snap["workers"]["per_worker"],
            "affinity": snap["workers"]["affinity"],
            "placement_skips": snap["workers"]["placement_skips"]}


def _scaleout_death_drill(n: int, rate: float, seed: int,
                          n_workers: int) -> dict:
    """Deterministic worker-death drill: Poisson load on a FakeClock
    against an inline-transport pool (each worker its own degradation
    ladder + breaker) with a seeded ``worker.die`` on worker 0 and one
    ``worker.stall`` watchdog trip on worker 1. Asserts the scale-out
    failure contract: the in-flight batch of the dead worker requeues
    whole onto survivors, every ticket resolves, and every served output
    stays bit-exact vs the numpy oracle. Everything reported is a pure
    function of (seed, n, rate, n_workers) — run it twice and diff."""
    from repro.serve.faults import FaultInjector, FaultPlan, FaultSpec
    from repro.serve.workers import WorkerPool

    models = _models("tiny")
    clock = FakeClock()
    metrics = ServeMetrics()
    plan = FaultPlan(seed=seed, specs=(
        FaultSpec("worker.die", key="0", after=5, times=1),
        FaultSpec("worker.stall", key="1", after=8, times=1, hang_s=1.0),
    ))
    inj = FaultInjector(plan, clock=clock)
    pool = WorkerPool(models, n_workers, transport="inline", clock=clock,
                      faults=inj, metrics=metrics,
                      fail_threshold=3, cooldown_s=0.08)
    eng = VTAServeEngine(models, clock=clock, metrics=metrics, faults=inj,
                         buckets=(1, 2, 4, 8), queue_capacity=n + 8,
                         max_retries=2, retry_backoff_s=0.004,
                         exec_timeout_s=0.5, requeue_budget=6,
                         workers=pool)
    for tenant, _, weight in TENANTS:
        eng.add_tenant(tenant, weight=weight)

    mix = _request_mix(models, n, seed)
    gaps = np.random.default_rng(seed + 13).exponential(1.0 / rate, n)
    tickets = []
    for k, (tenant, model, img, _) in enumerate(mix):
        clock.advance(float(gaps[k]))
        tickets.append(eng.submit(tenant, model, img))
        if k % 4 == 3 and eng.step():
            clock.advance(CHAOS_EXEC_COST_S)
    drained = 0
    while eng.pending() > 0 and drained < 20 * n:
        if eng.step():
            clock.advance(CHAOS_EXEC_COST_S)
        else:
            clock.advance(0.002)
        drained += 1

    unresolved = sum(1 for t in tickets if not t.done())
    checked = mismatches = 0
    for t in tickets:
        if not t.ok:
            continue
        ref = models[t.request.model].run_single(
            np.asarray(t.request.payload), backend="numpy")
        checked += 1
        if not np.array_equal(t.request.result, ref):
            mismatches += 1
    snap = metrics.snapshot()
    return {
        "requests": n, "rate": rate, "seed": seed, "workers": n_workers,
        "statuses": dict(sorted(Counter(t.status for t in tickets).items())),
        "unresolved": unresolved,
        "survivors": pool.live_count(),
        "fault_sites": inj.summary(),
        "fault_events": inj.events(),
        "per_worker": snap["workers"]["per_worker"],
        "affinity": snap["workers"]["affinity"],
        "placement_skips": snap["workers"]["placement_skips"],
        "worker_breakers": pool.breaker_log(),
        "reliability": snap["reliability"],
        "bitexact": {"checked": checked, "mismatches": mismatches},
    }


def _scaleout_phase(backend: str, buckets: tuple, n: int,
                    n_workers: int, device_latency: float, seed: int,
                    passes: int = 2, verbose: bool = True) -> dict:
    """Scale-out phase: (a) burst throughput at 1 worker vs ``n_workers``
    modeled accelerator instances over the thread transport — the speedup
    is the headline; (b) the deterministic death drill, run twice to prove
    same-seed byte-identical fault/metric logs."""
    endpoints = _scaleout_endpoints()
    mix = _scaleout_mix(endpoints, n, seed + 29)
    single = _scaleout_burst(endpoints, mix, backend, buckets, 1,
                             device_latency, passes)
    scaled = _scaleout_burst(endpoints, mix, backend, buckets, n_workers,
                             device_latency, passes)
    speedup = round(scaled["images_per_sec"]
                    / max(single["images_per_sec"], 1e-9), 2)
    drill = _scaleout_death_drill(n, 200.0, seed, n_workers)
    replay = _scaleout_death_drill(n, 200.0, seed, n_workers)
    deterministic = (json.dumps(drill, sort_keys=True)
                     == json.dumps(replay, sort_keys=True))
    out = {"workers": n_workers, "device_latency_s": device_latency,
           "burst": {"single": single, "scaled": scaled, "speedup": speedup},
           "death_drill": drill, "deterministic": deterministic}
    if verbose:
        print(f"  scaleout : 1 worker {single['images_per_sec']:7.1f} img/s"
              f" -> {n_workers} workers {scaled['images_per_sec']:7.1f}"
              f" img/s ({speedup}x), affinity hit-rate "
              f"{scaled['affinity']['hit_rate']}")
        print(f"             death drill: statuses {drill['statuses']}, "
              f"unresolved {drill['unresolved']}, survivors "
              f"{drill['survivors']}/{n_workers}, faults "
              f"{drill['fault_sites']}")
        print(f"             requeues {drill['reliability']['requeues']} "
              f"timeouts {drill['reliability']['timeouts']}, bit-exact "
              f"{drill['bitexact']['checked']} checked "
              f"{drill['bitexact']['mismatches']} mismatches, "
              f"replay-deterministic={deterministic}")
    return out


# ---------------------------------------------------------------------------
# baseline ratchet (deterministic chaos counters only — never wall clock)
# ---------------------------------------------------------------------------
_CHAOS_BASELINE_FIELDS = ("statuses", "unresolved", "poisoned",
                          "poisoned_failed", "fault_sites", "reliability",
                          "breaker", "breaker_recovered", "bitexact")


def write_json(out: dict, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def check_baseline(out: dict, baseline_dir: str) -> list:
    """Exact-compare the deterministic chaos fields against the checked-in
    baseline. Wall-clock and fault-event timestamps are never compared;
    a baseline recorded under different (seed, requests, ladder) knobs is
    skipped with a note rather than failed."""
    path = os.path.join(baseline_dir, "BENCH_serve.json")
    if not os.path.exists(path):
        return [f"no baseline at {path} (seed one with --json-out)"]
    with open(path) as f:
        base = json.load(f)
    cur, ref = out.get("chaos"), base.get("chaos")
    if cur is None or ref is None:
        return ["baseline check needs the chaos phase on both sides"]
    knobs = ("seed", "requests", "rate", "ladder")
    if any(cur.get(k) != ref.get(k) for k in knobs):
        print(f"  baseline : knob mismatch "
              f"({ {k: ref.get(k) for k in knobs} } vs current), skipping")
        return []
    errors = []
    for fieldname in _CHAOS_BASELINE_FIELDS:
        if cur.get(fieldname) != ref.get(fieldname):
            errors.append(
                f"chaos.{fieldname} drifted from baseline: "
                f"{ref.get(fieldname)!r} -> {cur.get(fieldname)!r}")
    return errors


def run(scale: str = "small", backend: str = "jax", requests: int = 96,
        poisson_requests: int = 48, rate: float = 100.0,
        buckets: tuple = (1, 2, 4, 8, 16), seed: int = 0,
        verify: int = 8, passes: int = 4,
        phases: tuple = DEFAULT_PHASES, chaos_requests: int = 48,
        chaos_rate: float = 200.0, ladder: Optional[tuple] = None,
        workers: int = 2, device_latency: float = DEVICE_LATENCY_S,
        verbose: bool = True) -> dict:
    phases = tuple(phases)
    unknown = set(phases) - set(DEFAULT_PHASES)
    if unknown:
        raise ValueError(f"unknown phases {sorted(unknown)}; "
                         f"known: {DEFAULT_PHASES}")
    out: dict = {"scale": scale, "backend": backend,
                 "buckets": list(buckets), "phases": list(phases)}
    if verbose:
        print(f"== bench_serve: scale={scale} backend={backend} "
              f"phases={','.join(phases)} ==")

    need_burst = {"throughput", "verify"} & set(phases)
    if need_burst:
        models = _models(scale)
        mix = _request_mix(models, requests, seed)
        batched, tickets = _throughput_phase(models, mix, backend, buckets,
                                             passes=passes)
        if "throughput" in phases:
            serial, _ = _throughput_phase(models, mix, backend, (1,),
                                          passes=passes)
            speedup = round(batched["images_per_sec"]
                            / max(serial["images_per_sec"], 1e-9), 2)
            out["throughput"] = {"batched": batched, "serialized": serial,
                                 "speedup": speedup}
            if verbose:
                print(f"  batched  : {batched['images_per_sec']:8.1f} img/s "
                      f"({batched['batches']} batches, occupancy "
                      f"{batched['batch_occupancy']:.2f})")
                print(f"  batch-1  : {serial['images_per_sec']:8.1f} img/s "
                      f"({serial['batches']} dispatches)")
                print(f"  -> continuous batching speedup {speedup}x")
        if "verify" in phases:
            out["verified"] = _verify_phase(models, mix, tickets, verify)
            if verbose:
                print(f"  verify   : {out['verified']['checked']} outputs "
                      f"vs batch-1 numpy, "
                      f"{out['verified']['mismatches']} mismatches")

    if "poisson" in phases:
        models = _models(scale)
        poisson = _poisson_phase(models, backend, buckets, poisson_requests,
                                 rate, seed)
        dropped = sum(poisson["requests"][k]
                      for k in ("rejected", "shed", "expired"))
        out["poisson"], out["dropped"] = poisson, dropped
        if verbose:
            lat = poisson["latency_s"]
            print(f"  poisson  : offered {rate}/s achieved "
                  f"{poisson['achieved_rate_rps']}/s, latency p50 "
                  f"{lat['p50'] * 1e3:.1f}ms p99 {lat['p99'] * 1e3:.1f}ms, "
                  f"occupancy {poisson['batch_occupancy']:.2f}, "
                  f"dropped {dropped}")
            for tenant, t in sorted(poisson["per_tenant"].items()):
                print(f"    {tenant:8s}: {t['completed']:4d} done, "
                      f"p99 {t['latency_s']['p99'] * 1e3:.1f}ms")

    if "chaos" in phases:
        from repro.vta.backend import DEGRADATION_LADDER
        out["chaos"] = _chaos_phase(chaos_requests, chaos_rate, seed,
                                    tuple(ladder or DEGRADATION_LADDER),
                                    verbose=verbose)

    if "scaleout" in phases:
        # tiny scale always: per-dispatch compute must stay under the
        # modeled device service floor for the scaling signal to be clean
        out["scaleout"] = _scaleout_phase(
            backend, buckets, requests, workers, device_latency, seed,
            passes=min(passes, 2), verbose=verbose)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.bench_serve")
    ap.add_argument("--scale", default="small", choices=("tiny", "small"))
    ap.add_argument("--backend", default="jax")
    ap.add_argument("--phases", default=",".join(DEFAULT_PHASES),
                    help="comma list from " + ",".join(DEFAULT_PHASES))
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--poisson-requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="poisson arrival rate, requests/s")
    ap.add_argument("--buckets", default="1,2,4,8,16")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", type=int, default=8,
                    help="outputs to check bit-exactly vs batch-1 numpy")
    ap.add_argument("--passes", type=int, default=4,
                    help="throughput passes; the fastest is reported")
    ap.add_argument("--chaos-requests", type=int, default=48)
    ap.add_argument("--chaos-rate", type=float, default=200.0)
    ap.add_argument("--ladder", default=None,
                    help="comma list of backends, best first "
                         "(default: the registered degradation ladder)")
    ap.add_argument("--workers", type=int, default=2,
                    help="pool size for the scaleout phase")
    ap.add_argument("--device-latency", type=float,
                    default=DEVICE_LATENCY_S,
                    help="modeled per-dispatch device service time (s) "
                         "for the scaleout burst")
    ap.add_argument("--json", default=None, help="write the report here")
    ap.add_argument("--json-out", default=None,
                    help="directory for the baseline-shaped "
                         "BENCH_serve.json")
    ap.add_argument("--check-baseline", default=None,
                    help="directory holding BENCH_serve.json to exact-"
                         "compare deterministic chaos counters against")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail unless batched/serialized reaches this")
    ap.add_argument("--min-scaleout-speedup", type=float, default=None,
                    help="fail unless the scaleout burst reaches this "
                         "N-worker/1-worker throughput ratio")
    ap.add_argument("--max-p99", type=float, default=None,
                    help="fail if poisson p99 latency exceeds this (s)")
    ap.add_argument("--assert-no-drops", action="store_true",
                    help="fail if any request was rejected/shed/expired")
    args = ap.parse_args(argv)
    out = run(scale=args.scale, backend=args.backend,
              requests=args.requests,
              poisson_requests=args.poisson_requests, rate=args.rate,
              buckets=tuple(int(b) for b in args.buckets.split(",")),
              seed=args.seed, verify=args.verify, passes=args.passes,
              phases=tuple(p for p in args.phases.split(",") if p),
              chaos_requests=args.chaos_requests,
              chaos_rate=args.chaos_rate,
              ladder=tuple(args.ladder.split(",")) if args.ladder else None,
              workers=args.workers, device_latency=args.device_latency)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"  report -> {args.json}")
    if args.json_out:
        print(f"  baseline -> {write_json(out, args.json_out)}")

    failures = []
    if out.get("verified", {}).get("mismatches"):
        failures.append(f"{out['verified']['mismatches']} outputs diverge "
                        f"from batch-1 numpy")
    if args.min_speedup is not None \
            and out["throughput"]["speedup"] < args.min_speedup:
        failures.append(f"speedup {out['throughput']['speedup']}x < "
                        f"required {args.min_speedup}x")
    if args.max_p99 is not None \
            and out["poisson"]["latency_s"]["p99"] > args.max_p99:
        failures.append(f"poisson p99 {out['poisson']['latency_s']['p99']}s "
                        f"> bound {args.max_p99}s")
    if args.assert_no_drops and out.get("dropped"):
        failures.append(f"{out['dropped']} requests dropped on an "
                        f"unsaturated load")
    chaos = out.get("chaos")
    if chaos is not None:
        if chaos["unresolved"]:
            failures.append(f"{chaos['unresolved']} tickets never resolved "
                            f"under chaos")
        if chaos["bitexact"]["mismatches"]:
            failures.append(f"{chaos['bitexact']['mismatches']} chaos "
                            f"outputs diverge from the numpy oracle")
        if len(chaos["poisoned"]) != chaos["poisoned_failed"]:
            failures.append(
                f"poisoned requests not all isolated+failed: "
                f"{chaos['poisoned_failed']}/{len(chaos['poisoned'])}")
        if not chaos["breaker_recovered"]:
            failures.append(f"breaker on {chaos['ladder'][0]} never "
                            f"recovered through a half-open probe")
    scaleout = out.get("scaleout")
    if scaleout is not None:
        drill = scaleout["death_drill"]
        if drill["unresolved"]:
            failures.append(f"{drill['unresolved']} tickets never resolved "
                            f"in the worker-death drill")
        if drill["bitexact"]["mismatches"]:
            failures.append(f"{drill['bitexact']['mismatches']} death-drill "
                            f"outputs diverge from the numpy oracle")
        if not scaleout["deterministic"]:
            failures.append("scaleout death drill is not replay-"
                            "deterministic (same-seed runs diverged)")
        if args.min_scaleout_speedup is not None \
                and scaleout["burst"]["speedup"] < args.min_scaleout_speedup:
            failures.append(
                f"scaleout speedup {scaleout['burst']['speedup']}x < "
                f"required {args.min_scaleout_speedup}x at "
                f"{scaleout['workers']} workers")
    if args.check_baseline:
        failures += check_baseline(out, args.check_baseline)
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
