"""Paper §IV.D.2 / Figs 11-12: redundant-load elimination.

Fig 11 counts bytes loaded into the *input and weight* scratchpads; the
paper measured the original (pre-TPS) virtual-threaded schedules, which
traverse output channels with the input chunk reloaded per step — the fix
removes every other load of the shared chunk (~50%). We report:
  * legacy-style schedules (core/tps.py::legacy_db_tiling): reproduces ~50%;
  * TPS schedules: the same fix recovers far less, because TPS has already
    minimized the redundant traffic — a reproduction *finding* (the two
    paper features overlap).
Fig 12: cycle deltas — gains on compute-heavy configs/large nets, slight
regressions (uop-load overhead) on small configs.
"""
from __future__ import annotations

from repro.core.tps import legacy_db_tiling
from repro.vta.isa import VTAConfig
from repro.vta.network import run_network
from repro.vta.workloads import resnet


def _cfg(log_block: int, mem_width: int = 16) -> VTAConfig:
    blk = log_block - 4
    return VTAConfig(log_block_in=log_block, log_block_out=log_block,
                     log_inp_buff=15 + blk, log_wgt_buff=18 + 2 * blk,
                     log_acc_buff=17 + blk, mem_width_bytes=mem_width,
                     gemm_ii=1, alu_ii=1)


def _inp_wgt(rep) -> int:
    return sum(l.bytes_by_buffer.get("inp", 0) + l.bytes_by_buffer.get("wgt", 0)
               for l in rep.layers if not l.on_cpu)


def run(depths=(18, 34, 50, 101), configs=((4, "1x16x16"), (5, "1x32x32")),
        verbose: bool = True) -> dict:
    results = []
    if verbose:
        print("== bench_double_buffer (paper Figs 11-12) ==")
    for lb, cfg_name in configs:
        hw = _cfg(lb)
        for depth in depths:
            layers = resnet(depth)
            runs = {}
            for style, tiling_fn in (("legacy", legacy_db_tiling),
                                     ("tps", None)):
                base = run_network(f"resnet{depth}", layers, hw,
                                   prefer_db=True, dedup_loads=False,
                                   tiling_fn=tiling_fn)
                dedup = run_network(f"resnet{depth}", layers, hw,
                                    prefer_db=True, dedup_loads=True,
                                    tiling_fn=tiling_fn)
                runs[style] = {
                    "iw_base": _inp_wgt(base), "iw_dedup": _inp_wgt(dedup),
                    "iw_reduction": 1 - _inp_wgt(dedup) / max(1, _inp_wgt(base)),
                    "cycles_base": base.total_cycles,
                    "cycles_dedup": dedup.total_cycles,
                    "cycle_delta": 1 - dedup.total_cycles
                        / max(1, base.total_cycles),
                }
            row = {"config": cfg_name, "net": f"resnet{depth}", **{
                f"{k}_{kk}": vv for k, v in runs.items() for kk, vv in v.items()}}
            results.append(row)
            if verbose:
                lg, tp = runs["legacy"], runs["tps"]
                print(f"  {cfg_name} resnet{depth:<3d}: "
                      f"legacy inp+wgt -{lg['iw_reduction']*100:5.1f}% "
                      f"cycles {'-' if lg['cycle_delta']>=0 else '+'}"
                      f"{abs(lg['cycle_delta'])*100:5.2f}%   |   "
                      f"TPS inp+wgt -{tp['iw_reduction']*100:5.1f}% "
                      f"cycles {'-' if tp['cycle_delta']>=0 else '+'}"
                      f"{abs(tp['cycle_delta'])*100:5.2f}%")
    if verbose:
        print("  [paper, pre-TPS schedules: bytes ~-50%; cycles -10% large "
              "nets / compute-heavy, slight increase on small configs]")
    return {"rows": results}


if __name__ == "__main__":
    run()
