"""Paper §IV.A / abstract: fully-pipelined GEMM+ALU => ~4.9x fewer cycles on
ResNet-18 (default 1x16x16 config), from a ~38M-cycle published baseline.

Published baseline model: 2-operand GEMM II=5 (pipeline depth 5, issue after
completion), unpipelined ALU (II 4/5), serial schedules, legacy clip
(SHR+MIN+MAX as 3 ALU passes). Enhanced: GEMM II=1, ALU II=1/2, virtual-
threaded schedules, fused CLIP instruction.
"""
from __future__ import annotations

from repro.vta.isa import VTAConfig
from repro.vta.network import run_network
from repro.vta.workloads import Layer, resnet


def legacy_layers(layers):
    return [Layer(l.kind, l.wl,
                  post_op=("clip_shift_legacy" if l.post_op == "clip_shift"
                           else l.post_op),
                  bias=l.bias, on_cpu=l.on_cpu) for l in layers]


def run(batch: int = 1, verbose: bool = True) -> dict:
    layers = resnet(18, batch)
    base_hw = VTAConfig(gemm_ii=5, alu_ii=4)       # as-published machine
    mid_hw = VTAConfig(gemm_ii=4, alu_ii=4)        # II=4 reading of the paper
    pipe_hw = VTAConfig(gemm_ii=1, alu_ii=1)       # §IV.A.1-2

    base = run_network("resnet18", legacy_layers(layers), base_hw,
                       prefer_db=False)
    mid = run_network("resnet18", legacy_layers(layers), mid_hw,
                      prefer_db=False)
    pipe = run_network("resnet18", layers, pipe_hw, prefer_db=True)

    out = {
        "published_baseline_cycles": base.total_cycles,
        "ii4_baseline_cycles": mid.total_cycles,
        "pipelined_cycles": pipe.total_cycles,
        "speedup_vs_published": base.total_cycles / pipe.total_cycles,
        "speedup_vs_ii4": mid.total_cycles / pipe.total_cycles,
        "paper_baseline_cycles": 38e6,
        "paper_speedup": 4.9,
    }
    if verbose:
        print("== bench_pipelining (paper §IV.A: ~38M cycles, ~4.9x) ==")
        print(f"  published baseline (GEMM II=5, ALU 4/5, serial, legacy clip): "
              f"{base.total_cycles/1e6:7.2f}M cycles   [paper: ~38M]")
        print(f"  II=4 reading of the baseline:                                "
              f"{mid.total_cycles/1e6:7.2f}M cycles")
        print(f"  pipelined + enhanced (GEMM II=1, ALU 1/2, vthreads, clip):   "
              f"{pipe.total_cycles/1e6:7.2f}M cycles")
        print(f"  speedup: {out['speedup_vs_published']:.2f}x vs published, "
              f"{out['speedup_vs_ii4']:.2f}x vs II=4   [paper: ~4.9x]")
    return out


if __name__ == "__main__":
    run()
