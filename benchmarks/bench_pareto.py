"""Paper §IV.F / Fig 13: area-cycle design space for ResNet-18.

Sweeps GEMM shape (4x4 / 5x5 / 6x6 in log2, the paper's three ovals), memory
interface width (8..64B) and scratchpad scale via the parallel DSE engine
(`repro.core.dse.run_sweep`); reports the pareto frontier and the big-end
point (paper: ~11.5x fewer cycles at ~12x area vs the pipelined default).

Pass `cache_dir` to make repeat runs incremental (the engine's
content-addressed cache); the default is a fresh in-memory sweep.
"""
from __future__ import annotations

from typing import Optional

from repro.core.dse import run_sweep
from repro.vta.workloads import resolve_network


def run(verbose: bool = True, spad_scales=(1, 2, 4), batch_logs=(0,),
        networks=("resnet18",), cache_dir: Optional[str] = None) -> dict:
    res = run_sweep(networks, out_dir=cache_dir, spad_scales=spad_scales,
                    batch_logs=batch_logs, per_layer=False)
    full = res.report()
    rep = full["per_network"][resolve_network(networks[0])]
    out = {
        "n_points": rep["n_points"],
        "pareto": rep["pareto"],
        "ref": rep["ref"],
        "best": rep["best"],
        "cycle_gain_best": rep["cycle_gain_best"],
        "area_cost_best": rep["area_cost_best"],
        "area_span": rep["area_span"],
    }
    if len(res.networks) > 1:
        out["joint"] = full["joint"]
    if verbose:
        print("== bench_pareto (paper Fig 13) ==")
        print(f"  {out['n_points']} feasible configurations "
              f"(area span {out['area_span']:.1f}x)")
        print("  pareto frontier (area_scaled, cycles):")
        for label, a, c in out["pareto"]:
            print(f"    {label:22s} area {a:6.2f}x  cycles {c/1e6:7.2f}M")
        ref_label, ref_area, ref_cycles = out["ref"]
        print(f"  reference {ref_label}: area 1.0x, "
              f"{ref_cycles/1e6:.2f}M cycles")
        print(f"  big end   {out['best'][0]}: {out['cycle_gain_best']:.1f}x fewer "
              f"cycles at {out['area_cost_best']:.1f}x area  "
              f"[paper: ~11.5x at ~12x]")
    return out


if __name__ == "__main__":
    run()
