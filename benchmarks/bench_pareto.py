"""Paper §IV.F / Fig 13: area-cycle design space for ResNet-18.

Sweeps GEMM shape (4x4 / 5x5 / 6x6 in log2, the paper's three ovals), memory
interface width (8..64B) and scratchpad scale; reports the pareto frontier
and the big-end point (paper: ~11.5x fewer cycles at ~12x area vs the
pipelined default)."""
from __future__ import annotations

from repro.core.dse import DSEPoint, make_config, pareto, sweep
from repro.vta.workloads import resnet


def run(verbose: bool = True, spad_scales=(1, 2, 4), batch_logs=(0,)) -> dict:
    layers = resnet(18)
    ref = make_config()     # pipelined 1x16x16, 8B bus
    points = sweep(layers, reference=ref, spad_scales=spad_scales,
                   batch_logs=batch_logs)
    front = pareto(points)
    ref_pt = min((p for p in points if p.hw.log_block_in == 4
                  and p.hw.mem_width_bytes == 8), key=lambda p: p.area)
    best = min(points, key=lambda p: p.cycles)
    out = {
        "n_points": len(points),
        "pareto": [(p.label, p.area, p.cycles) for p in front],
        "ref": (ref_pt.label, ref_pt.area, ref_pt.cycles),
        "best": (best.label, best.area, best.cycles),
        "cycle_gain_best": ref_pt.cycles / best.cycles,
        "area_cost_best": best.area / ref_pt.area,
        "area_span": max(p.area for p in points) / min(p.area for p in points),
    }
    if verbose:
        print("== bench_pareto (paper Fig 13) ==")
        print(f"  {len(points)} feasible configurations "
              f"(area span {out['area_span']:.1f}x)")
        print("  pareto frontier (area_scaled, cycles):")
        for label, a, c in out["pareto"]:
            print(f"    {label:22s} area {a:6.2f}x  cycles {c/1e6:7.2f}M")
        print(f"  reference {ref_pt.label}: area 1.0x, "
              f"{ref_pt.cycles/1e6:.2f}M cycles")
        print(f"  big end   {best.label}: {out['cycle_gain_best']:.1f}x fewer "
              f"cycles at {out['area_cost_best']:.1f}x area  "
              f"[paper: ~11.5x at ~12x]")
    return out


if __name__ == "__main__":
    run()
