"""Paper §IV.F / Fig 13: area-cycle design space for ResNet-18.

Sweeps GEMM shape (4x4 / 5x5 / 6x6 in log2, the paper's three ovals), memory
interface width (8..64B) and scratchpad scale via the parallel DSE engine
(`repro.core.dse.run_sweep`); reports the pareto frontier and the big-end
point (paper: ~11.5x fewer cycles at ~12x area vs the pipelined default).

Pass `cache_dir` to make repeat runs incremental (the engine's
content-addressed cache); the default is a fresh in-memory sweep.

`--staging` (implied by `--json-out` / `--check-baseline`) instead
benchmarks the *sweep engine itself* on a reduced joint grid: cold- and
warm-cache wall time, the per-stage breakdown (schedule / autotune /
tsim-cost / fsim-verify), schedule-store sharing counters and a
content digest over every produced point record. The digest plus the
deterministic counters are what ``--check-baseline`` ratchets against
``benchmarks/baselines/BENCH_dse.json`` — wall clock is recorded but
never compared (machine-dependent):

  PYTHONPATH=src python -m benchmarks.bench_pareto \
      --json-out results/bench --check-baseline benchmarks/baselines
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import shutil
import sys
import tempfile
import time
from typing import Optional

from repro.core.dse import run_sweep
from repro.vta.workloads import resolve_network

STAGING_GRID = {"networks": ["resnet18"], "log_blocks": [4, 6],
                "mem_widths": [8, 16, 32, 64], "spad_scales": [1],
                "pipelined": [1, 0], "tune": "cached", "workers": 1}


def run(verbose: bool = True, spad_scales=(1, 2, 4), batch_logs=(0,),
        networks=("resnet18",), cache_dir: Optional[str] = None) -> dict:
    res = run_sweep(networks, out_dir=cache_dir, spad_scales=spad_scales,
                    batch_logs=batch_logs, per_layer=False)
    full = res.report()
    rep = full["per_network"][resolve_network(networks[0])]
    out = {
        "n_points": rep["n_points"],
        "pareto": rep["pareto"],
        "ref": rep["ref"],
        "best": rep["best"],
        "cycle_gain_best": rep["cycle_gain_best"],
        "area_cost_best": rep["area_cost_best"],
        "area_span": rep["area_span"],
    }
    if len(res.networks) > 1:
        out["joint"] = full["joint"]
    if verbose:
        print("== bench_pareto (paper Fig 13) ==")
        print(f"  {out['n_points']} feasible configurations "
              f"(area span {out['area_span']:.1f}x)")
        print("  pareto frontier (area_scaled, cycles):")
        for label, a, c in out["pareto"]:
            print(f"    {label:22s} area {a:6.2f}x  cycles {c/1e6:7.2f}M")
        ref_label, ref_area, ref_cycles = out["ref"]
        print(f"  reference {ref_label}: area 1.0x, "
              f"{ref_cycles/1e6:.2f}M cycles")
        print(f"  big end   {out['best'][0]}: {out['cycle_gain_best']:.1f}x fewer "
              f"cycles at {out['area_cost_best']:.1f}x area  "
              f"[paper: ~11.5x at ~12x]")
    return out


# ---------------------------------------------------------------------------
# Sweep-engine staging bench (--staging / --json-out / --check-baseline)
# ---------------------------------------------------------------------------
def points_digest(records: list) -> str:
    """Order-independent content digest over point records.

    ``label`` is presentation (unpipelined points grew a ``/np`` suffix)
    and ``schema`` is a cache stamp; everything else — cycles, DRAM
    bytes, per-layer breakdowns, configs — must be byte-identical for
    the digest to match, which is exactly the staged-caching contract.
    """
    norm = [{k: v for k, v in r.items() if k not in ("label", "schema")}
            for r in records]
    norm.sort(key=lambda r: json.dumps(r, sort_keys=True))
    return hashlib.sha256(
        json.dumps(norm, sort_keys=True).encode()).hexdigest()


def _collect_records(out_dir: str) -> list:
    cdir = os.path.join(out_dir, "cache")
    recs = []
    for n in sorted(os.listdir(cdir)):
        if n.endswith(".json"):
            with open(os.path.join(cdir, n)) as f:
                recs.append(json.load(f))
    return recs


def run_staging(verbose: bool = True,
                out_dir: Optional[str] = None) -> dict:
    """Cold + warm engine run on the reduced joint grid (STAGING_GRID)."""
    grid = STAGING_GRID
    kw = dict(log_blocks=tuple(grid["log_blocks"]),
              mem_widths=tuple(grid["mem_widths"]),
              spad_scales=tuple(grid["spad_scales"]),
              pipelined=tuple(bool(p) for p in grid["pipelined"]),
              tune=grid["tune"], workers=grid["workers"])
    work = out_dir or tempfile.mkdtemp(prefix="bench_dse_")
    try:
        shutil.rmtree(work, ignore_errors=True)
        t0 = time.perf_counter()
        cold = run_sweep(grid["networks"], out_dir=work, profile=True, **kw)
        cold_wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_sweep(grid["networks"], out_dir=work, **kw)
        warm_wall = time.perf_counter() - t0
        records = _collect_records(work)
    finally:
        if out_dir is None:
            shutil.rmtree(work, ignore_errors=True)
    prof = cold.profile or {}
    store = prof.get("schedule_store", {})
    out = {
        "grid": grid,
        "cold_wall_s": round(cold_wall, 2),
        "warm_wall_s": round(warm_wall, 2),
        "stages_s": prof.get("stages", {}),
        "n_records": len(records),
        "n_feasible": sum(1 for r in records if r.get("feasible")),
        "points_digest": points_digest(records),
        # deterministic engine counters (what the baseline ratchets):
        # misses = programs actually scheduled, hits = cost-model replays
        "programs_scheduled": store.get("misses", 0),
        "cost_replays": store.get("hits", 0),
        "store_evictions": store.get("evictions", 0),
    }
    if verbose:
        print("== bench_pareto --staging (sweep-engine wall time) ==")
        print(f"  grid: {len(grid['log_blocks'])} geometries x "
              f"{len(grid['mem_widths'])} mem widths x "
              f"{len(grid['pipelined'])} pipelining settings "
              f"({out['n_records']} points, {out['n_feasible']} feasible)")
        print(f"  cold {out['cold_wall_s']:.1f}s / warm "
              f"{out['warm_wall_s']:.2f}s")
        br = "  ".join(f"{k} {v:.1f}s"
                       for k, v in sorted(out["stages_s"].items()))
        print(f"  stages: {br}")
        print(f"  schedule store: {out['programs_scheduled']} programs "
              f"scheduled, {out['cost_replays']} cost replays, "
              f"{out['store_evictions']} evictions")
        print(f"  points digest: {out['points_digest'][:16]}…")
    return out


def write_json(out: dict, dirpath: str) -> str:
    os.makedirs(dirpath, exist_ok=True)
    path = os.path.join(dirpath, "BENCH_dse.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    return path


def check_baseline(out: dict, baseline_dir: str) -> list:
    """Ratchet vs the checked-in BENCH_dse.json (deterministic facts only).

    * ``points_digest`` must match exactly: the staged engine must keep
      every DSEPoint byte-identical to the recorded sweep;
    * ``programs_scheduled`` may not grow: a regression here means
      cost-variant sharing broke and the engine went back to
      re-scheduling per variant;
    * point counts must match. Wall-clock fields are informational.
    A baseline recorded under a different grid is skipped.
    """
    path = os.path.join(baseline_dir, "BENCH_dse.json")
    if not os.path.exists(path):
        return [f"no baseline at {path} (seed one with --json-out)"]
    with open(path) as f:
        base = json.load(f)
    if base.get("grid") != out["grid"]:
        print(f"  (baseline grid differs — skipping ratchet: {path})")
        return []
    errs = []
    if out["points_digest"] != base["points_digest"]:
        errs.append(f"points digest changed: {base['points_digest']} -> "
                    f"{out['points_digest']} (sweep output is no longer "
                    f"byte-identical)")
    if out["n_feasible"] != base["n_feasible"]:
        errs.append(f"feasible points changed: {base['n_feasible']} -> "
                    f"{out['n_feasible']}")
    if out["programs_scheduled"] > base["programs_scheduled"]:
        errs.append(f"programs scheduled regressed: "
                    f"{base['programs_scheduled']} -> "
                    f"{out['programs_scheduled']} (schedule sharing across "
                    f"cost variants degraded)")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.bench_pareto")
    ap.add_argument("--staging", action="store_true",
                    help="benchmark the sweep engine (cold/warm wall, stage "
                         "breakdown) instead of reporting Fig-13 numbers")
    ap.add_argument("--json-out", default=None,
                    help="directory to write BENCH_dse.json into "
                         "(implies --staging)")
    ap.add_argument("--check-baseline", default=None,
                    help="directory holding the checked-in BENCH_dse.json "
                         "(implies --staging)")
    ap.add_argument("--out", default=None,
                    help="work dir for the staging sweep (default: a "
                         "scratch dir, removed afterwards)")
    args = ap.parse_args(argv)

    if not (args.staging or args.json_out or args.check_baseline):
        run()
        return 0
    out = run_staging(out_dir=args.out)
    rc = 0
    if args.check_baseline:
        base_path = os.path.join(args.check_baseline, "BENCH_dse.json")
        if os.path.exists(base_path):
            with open(base_path) as f:
                base = json.load(f)
            ref = base.get("pre_staging_cold_wall_s")
            if ref:
                out["pre_staging_cold_wall_s"] = ref
                out["speedup_vs_pre_staging"] = round(
                    ref / max(out["cold_wall_s"], 1e-9), 2)
                print(f"  vs pre-staging engine: "
                      f"{out['speedup_vs_pre_staging']}x faster cold "
                      f"({ref}s -> {out['cold_wall_s']}s)")
        errs = check_baseline(out, args.check_baseline)
        for e in errs:
            print(f"BASELINE VIOLATION: {e}", file=sys.stderr)
        rc = 1 if errs else 0
    if args.json_out:
        print(f"wrote {write_json(out, args.json_out)}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
